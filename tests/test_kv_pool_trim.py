"""``PagedKVPool.trim`` under adversarial aliasing (DESIGN.md §14).

The speculative plane leans on ``trim`` every step (rejected draft
tails), and the radix tree has always used it for partial node
eviction — these regressions pin its refcount semantics when the
trimmed table ALIASES other tables: CoW partial-tail boundaries,
refcounted ``("node", key)`` prefix shares, and trims racing
in-flight prefetch/demote style mutations of the peer table.
"""

import numpy as np
import pytest

from repro.serving.kv_cache import PagedKVPool

PS = 8


def _pool(n=32):
    return PagedKVPool(n, PS)


# ---------------------------------------------------------------------------
# clamp / boundary basics the spec plane depends on
# ---------------------------------------------------------------------------

def test_trim_beyond_length_is_noop_clamp():
    """accepted == k_eff in the spec plane trims to MORE tokens than the
    table holds (dK was never fed back): must free nothing and keep
    num_tokens unchanged."""
    p = _pool()
    p.create("a")
    p.append("a", 13)
    before = (list(p.tables["a"].pages), p.free_pages)
    assert p.trim("a", 20) == 0
    assert p.tables["a"].num_tokens == 13
    assert (list(p.tables["a"].pages), p.free_pages) == before
    p.check_invariants()


def test_trim_keeps_partial_boundary_page():
    p = _pool()
    p.create("a")
    p.append("a", 3 * PS)
    assert p.trim("a", PS + 1) == 1          # only the third page frees
    assert len(p.tables["a"].pages) == 2     # partial page 2 survives
    assert p.tables["a"].num_tokens == PS + 1
    p.check_invariants()


def test_trim_to_zero_frees_everything():
    p = _pool()
    p.create("a")
    p.append("a", 2 * PS + 5)
    free0 = p.free_pages
    assert p.trim("a", 0) == 3
    assert p.tables["a"].pages == [] and p.tables["a"].num_tokens == 0
    assert p.free_pages == free0 + 3
    p.append("a", 10)                        # table is reusable after
    assert p.tables["a"].num_tokens == 10
    p.check_invariants()


# ---------------------------------------------------------------------------
# CoW boundary
# ---------------------------------------------------------------------------

def test_trim_across_cow_boundary_preserves_peer_tail():
    """Parent and child share a PARTIAL tail page; the child CoWs it on
    append. Trimming the parent through that boundary must free only
    the parent's private copy-side pages and decrement — never free —
    anything the child still references."""
    p = _pool()
    p.create("parent")
    p.append("parent", 2 * PS + 4)           # pages [0,1,2], page 2 partial
    p.fork("parent", "child")                # all 3 shared, refcount 2
    p.append("child", 6)                     # CoW: child copies page 2
    child_pages = list(p.tables["child"].pages)
    parent_pages = list(p.tables["parent"].pages)
    assert child_pages[:2] == parent_pages[:2]
    assert child_pages[2] != parent_pages[2], "CoW must have copied"
    p.check_invariants()

    # trim the parent through the CoW boundary into the shared region
    freed = p.trim("parent", PS + 2)         # keep pages [0,1(partial)]
    assert freed == 1                        # only the parent's page 2
    assert p.tables["child"].pages == child_pages, \
        "trimming the parent disturbed the child's pages"
    assert p.refcount[child_pages[0]] == 2   # still shared
    assert p.refcount[child_pages[1]] == 2
    p.check_invariants()

    # and through the fully-shared region: pages must NOT free (child
    # holds them), only the parent's reference drops
    freed = p.trim("parent", 0)
    assert freed == 0, "shared pages freed while the child references them"
    assert p.refcount[child_pages[0]] == 1
    p.check_invariants()
    p.release("child")
    p.release("parent")
    assert p.free_pages == p.num_pages
    p.check_invariants()


# ---------------------------------------------------------------------------
# ("node", key) alias overlap — the radix tree's table keying
# ---------------------------------------------------------------------------

def test_trim_request_overlapping_node_alias():
    """A request table forked from a cached ``("node", key)`` table (the
    engine's admission alias): trimming the request back through the
    shared prefix must leave every node page resident (refcount 1),
    and trimming the NODE's unshared tail must not disturb the
    request."""
    p = _pool()
    node = ("node", ("prefix", 42))
    p.create(node)
    p.append(node, 4 * PS)                   # 4 whole pages
    p.fork(node, ("req", 1), shared_tokens=2 * PS + 3)
    req = p.tables[("req", 1)]
    assert len(req.pages) == 3               # 2 whole + partial boundary
    p.append(("req", 1), PS)                 # CoW page 2 + grow
    p.check_invariants()

    node_pages = list(p.tables[node].pages)
    # request rolls back its speculative tail through the shared prefix
    p.trim(("req", 1), PS + 1)
    assert p.tables[node].pages == node_pages, "node lost pages"
    assert all(pg in p.refcount for pg in node_pages)
    assert p.refcount[node_pages[0]] == 2    # still aliased
    assert p.refcount[node_pages[2]] == 1    # req's CoW dropped its ref
    p.check_invariants()

    # partial node eviction (the tree trims the cached tail) while the
    # request still aliases the head
    p.trim(node, PS)
    assert p.tables[("req", 1)].num_tokens == PS + 1
    assert p.refcount[node_pages[0]] == 2
    p.check_invariants()
    p.release(("req", 1))
    p.release(node)
    assert p.free_pages == p.num_pages


# ---------------------------------------------------------------------------
# trim racing in-flight prefetch/demote mutations of the peer table
# ---------------------------------------------------------------------------

def test_trim_races_prefetch_append_on_aliased_node():
    """The prefetch stream appends restored tokens into a node table
    in-flight while a request aliasing its head trims (rejected spec
    tail) and releases — interleaved, repeatedly. Refcounts must stay
    exact and no shared page may ever hit the free list early."""
    p = _pool(64)
    node = ("node", "doc")
    p.create(node)
    p.append(node, 2 * PS)                   # restored so far
    p.fork(node, ("req", 7), shared_tokens=2 * PS)
    p.append(("req", 7), 5)                  # private decode tail
    shared = list(p.tables[node].pages)

    p.append(node, PS + 3)                   # prefetch lands mid-step
    p.trim(("req", 7), 2 * PS + 1)           # spec rollback, keeps alias
    p.check_invariants()
    assert p.tables[node].pages[:2] == shared
    assert p.refcount[shared[0]] == 2

    p.append(node, 5)                        # second prefetch chunk...
    p.trim(("req", 7), PS)                   # ...racing a deeper trim
    p.check_invariants()
    assert p.refcount[shared[0]] == 2 and p.refcount[shared[1]] == 1

    # demote completes: the node's device copy trims away entirely;
    # the request's aliased head must keep its page alive
    p.trim(node, 0)
    assert shared[0] in p.refcount and p.refcount[shared[0]] == 1
    assert shared[1] not in p.refcount       # truly unreferenced -> freed
    p.check_invariants()
    p.release(("req", 7))
    p.release(node)
    assert p.free_pages == p.num_pages


def test_randomized_trim_fork_append_interleavings():
    """Property-style sweep: random interleavings of create / fork /
    append / trim / release across aliased tables never violate the
    pool invariants, and a full drain returns every page."""
    rng = np.random.default_rng(0)
    for _ in range(40):
        p = _pool(48)
        ids, next_id = [], 0
        for step in range(rng.integers(8, 25)):
            op = rng.integers(0, 5)
            if op == 0 or not ids:
                sid, next_id = ("t", next_id), next_id + 1
                p.create(sid)
                ids.append(sid)
                try:
                    p.append(sid, int(rng.integers(1, 3 * PS)))
                except MemoryError:
                    p.release(sid)
                    ids.remove(sid)
            elif op == 1 and ids:
                parent = ids[rng.integers(len(ids))]
                sid, next_id = ("t", next_id), next_id + 1
                share = int(rng.integers(
                    0, p.tables[parent].num_tokens + 1))
                p.fork(parent, sid, shared_tokens=share)
                ids.append(sid)
            elif op == 2:
                sid = ids[rng.integers(len(ids))]
                try:
                    p.append(sid, int(rng.integers(1, 2 * PS)))
                except MemoryError:
                    pass                     # pool squeeze: fine, no-op
            elif op == 3:
                sid = ids[rng.integers(len(ids))]
                p.trim(sid, int(rng.integers(
                    0, p.tables[sid].num_tokens + PS)))
            else:
                sid = ids.pop(rng.integers(len(ids)))
                p.release(sid)
            p.check_invariants()
        for sid in ids:
            p.release(sid)
        assert p.free_pages == p.num_pages
        p.check_invariants()
