"""Fused ragged mixed prefill+decode iterations (DESIGN.md §7).

Token-exactness vs the dense oracle for randomized mixes of chunk
sizes, reuse boundaries (page-aligned and not) and decode slots;
decode lanes never starve under a prefill flood; model dispatches per
iteration are O(1) in the number of active prefills; and a hypothesis
property test that pool refcounts and radix pin lists stay consistent
under interleaved admit/step/evict sequences of the mixed scheduler.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.request import Request
from repro.models import zoo
from repro.serving.engine import Engine, EngineConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(reduced(ARCHS["smollm-360m"]), n_layers=2,
                              dtype="float32")
    api = zoo.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _econf(paged, fused=None, **kw):
    base = dict(max_context=96, chunk_size=16, max_batch_tokens=96,
                max_batch_requests=16, capacity_tokens=8192, page_size=16,
                paged=paged, fused=fused)
    base.update(kw)
    return EngineConfig(**base)


def _drive(eng, waves, max_iters=2000):
    """waves: [(enqueue_at_iteration, requests)] — staggered arrivals so
    later prefills land while earlier requests decode (mixed steps)."""
    done, now = [], 0.0
    total = sum(len(rs) for _, rs in waves)
    for it in range(max_iters):
        for at, rs in waves:
            if at == it:
                for r in rs:
                    eng.scheduler.enqueue(r, now)
        done += eng.step(now)
        now += 0.01
        if len(done) == total and it >= max(at for at, _ in waves):
            break
    assert len(done) == total, "requests did not finish"
    return done


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fused_mixed_matches_dense_oracle(small_model, seed):
    """Randomized mixes — chunk size, shared-prefix length (page-aligned
    and CoW boundaries), tail lengths, decode budgets — through the
    fused paged plane vs the dense reference: outputs must be
    token-identical."""
    cfg, api, params = small_model
    rng = np.random.default_rng(seed)
    chunk = int(rng.choice([8, 16, 24]))
    shared_len = int(rng.choice([16, 23, 32, 41]))   # aligned + mid-page
    shared = tuple(rng.integers(1, cfg.vocab_size, shared_len).tolist())

    def wave(n, seed2):
        rr = np.random.default_rng(seed2)
        return [Request(tokens=shared
                        + tuple(rr.integers(1, cfg.vocab_size,
                                            int(rr.integers(4, 20)))
                                .tolist()),
                        max_new_tokens=int(rr.integers(3, 8)))
                for _ in range(n)]

    outs = {}
    for paged in (False, True):
        eng = Engine(cfg, params, _econf(paged, chunk_size=chunk))
        if paged:
            assert eng.fused, "paged plane must default to fused"
        done = _drive(eng, [(0, wave(3, seed + 10)),
                            (4, wave(4, seed + 20))])
        assert eng.stats["reused_tokens"] > 0, "cache never hit"
        if paged:
            assert eng.stats["fused_iterations"] > 0, \
                "mixed steps never took the fused path"
            eng.pool.check_invariants()
        outs[paged] = {(tuple(r.tokens), r.max_new_tokens):
                       list(r.output_tokens) for r in done}
    assert outs[True] == outs[False]


def test_decode_lanes_advance_under_prefill_flood(small_model):
    """Starvation freedom: while a flood of long prefills is queued,
    every decode lane must still emit exactly one token per iteration
    (the fused step always packs decode slots first)."""
    cfg, api, params = small_model
    eng = Engine(cfg, params, _econf(True, max_batch_tokens=64,
                                     max_batch_requests=24,
                                     capacity_tokens=16384))
    rng = np.random.default_rng(0)
    deco = [Request(tokens=tuple(rng.integers(1, cfg.vocab_size, 8)
                                 .tolist()), max_new_tokens=40)
            for _ in range(4)]
    now = 0.0
    for r in deco:
        eng.scheduler.enqueue(r, now)
    while not (len(eng.scheduler.running) == len(deco)
               and not eng.scheduler.prefilling
               and not eng.scheduler.waiting):
        eng.step(now)
        now += 0.01
    flood = [Request(tokens=tuple(rng.integers(1, cfg.vocab_size, 80)
                                  .tolist()), max_new_tokens=2)
             for _ in range(12)]
    for r in flood:
        eng.scheduler.enqueue(r, now)
    f0 = eng.stats["fused_iterations"]
    for _ in range(10):
        before = [len(r.output_tokens) for r in deco]
        eng.step(now)
        now += 0.01
        after = [len(r.output_tokens) for r in deco]
        assert all(a == b + 1 for b, a in zip(before, after)), \
            "a decode lane starved during the prefill flood"
        assert eng.scheduler.prefilling or eng.scheduler.waiting, \
            "flood drained too early for the test to mean anything"
    assert eng.stats["fused_iterations"] - f0 == 10, \
        "flood iterations must all run fused"


def test_fused_dispatches_are_o1_in_active_prefills(small_model):
    """Acceptance gate: on the fused plane, model dispatches per
    iteration are O(1) no matter how many prefills are packed; the
    unfused PR-1 loop pays one dispatch per prefill item."""
    cfg, api, params = small_model
    stats, outs = {}, {}
    for fused in (True, False):
        eng = Engine(cfg, params, _econf(True, fused=fused, chunk_size=8,
                                         max_batch_tokens=128))
        rng = np.random.default_rng(1)
        reqs = [Request(tokens=tuple(rng.integers(1, cfg.vocab_size, 40)
                                     .tolist()), max_new_tokens=2)
                for _ in range(10)]
        now, done = 0.0, []
        for r in reqs:
            eng.scheduler.enqueue(r, now)
        while len(done) < len(reqs):
            done += eng.step(now)
            now += 0.01
        stats[fused] = dict(eng.stats)
        outs[fused] = {tuple(r.tokens): list(r.output_tokens)
                       for r in done}
    assert stats[True]["model_dispatches"] <= stats[True]["iterations"], \
        "fused plane must run at most one dispatch per iteration"
    assert stats[True]["fused_iterations"] > 0
    assert stats[False]["model_dispatches"] > \
        2 * stats[False]["iterations"], \
        "unfused baseline should pay per-prefill dispatches (else the " \
        "fused comparison is vacuous)"
    assert outs[True] == outs[False], "fused and unfused planes diverged"


@pytest.mark.slow
def test_pool_and_pins_consistent_under_interleaving(small_model):
    """Property: page-pool refcounts/free-list and radix pin lists stay
    consistent under arbitrary interleavings of admit / step / evict on
    the mixed scheduler, and a full drain releases every request table
    and pin."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cfg, api, params = small_model

    @settings(max_examples=8, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 2),      # op kind
                              st.integers(0, 3),      # prefix choice
                              st.integers(1, 12)),    # size / step count
                    min_size=4, max_size=16),
           st.integers(0, 2 ** 31 - 1))
    def run(ops, seed):
        rng = np.random.default_rng(seed)
        prefixes = [tuple(rng.integers(1, cfg.vocab_size, n).tolist())
                    for n in (8, 17, 24, 32)]
        eng = Engine(cfg, params, _econf(True, max_context=64,
                                         chunk_size=16,
                                         max_batch_tokens=64,
                                         capacity_tokens=640,
                                         page_size=8))
        now, live = 0.0, []
        for op, pi, n in ops:
            if op == 0:                       # admit
                tail = tuple(rng.integers(1, cfg.vocab_size, n).tolist())
                r = Request(tokens=(prefixes[pi] + tail)[:48],
                            max_new_tokens=3)
                eng.scheduler.enqueue(r, now)
                live.append(r)
            elif op == 1:                     # step
                for _ in range(n % 4 + 1):
                    eng.step(now)
                    now += 0.01
            else:                             # eviction pressure
                plan = eng.scheduler.tree.plan_eviction(0, n * 8)
                if plan:
                    eng.scheduler.apply_eviction(plan)
            eng.pool.check_invariants()
            assert eng.scheduler.used_tokens >= 0
            assert all(node.ref_count >= 0
                       for node in eng.scheduler.tree.iter_nodes())
        for _ in range(2000):
            if all(r.state.value in ("finished", "failed") for r in live):
                break
            eng.step(now)
            now += 0.01
        assert all(r.state.value in ("finished", "failed") for r in live)
        eng.pool.check_invariants()
        assert not any(isinstance(k, tuple) and k[0] == "req"
                       for k in eng.pool.tables), "leaked request tables"
        assert not any(path for path in eng.scheduler._pinned.values()), \
            "pin lists survived a full drain"

    run()
