"""Shared pytest plumbing for the suite.

The full suite compiles a few hundred distinct XLA executables in one
process (every module builds its own reduced models and jits).  Left
unbounded, that accumulated native state can segfault jaxlib's CPU
compiler deep into the run — deterministically, on whichever test
crosses the threshold.  Dropping the jit/executable caches at module
boundaries keeps peak in-process XLA state bounded by the heaviest
single module; cross-module cache reuse is near zero anyway because
each module uses its own reduced configs.
"""

import gc
import os

# The SPMD data-plane tests (tests/test_spmd_engine.py) need a real
# multi-device mesh; on CPU runners that is emulated by asking XLA for
# 8 host-platform devices BEFORE jax initializes its backend (the flag
# is read once, at first device use). Single-device tests are
# unaffected: uncommitted arrays still land on device 0 and nothing
# shards unless a mesh is built explicitly.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_xla_state_per_module():
    yield
    jax.clear_caches()
    gc.collect()
