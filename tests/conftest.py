"""Shared pytest plumbing for the suite.

The full suite compiles a few hundred distinct XLA executables in one
process (every module builds its own reduced models and jits).  Left
unbounded, that accumulated native state can segfault jaxlib's CPU
compiler deep into the run — deterministically, on whichever test
crosses the threshold.  Dropping the jit/executable caches at module
boundaries keeps peak in-process XLA state bounded by the heaviest
single module; cross-module cache reuse is near zero anyway because
each module uses its own reduced configs.
"""

import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_xla_state_per_module():
    yield
    jax.clear_caches()
    gc.collect()
