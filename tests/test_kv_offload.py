"""Hierarchical KV tiering (DESIGN.md §8): host-offload store unit
tests, demote/restore token-exactness against the dense oracle under
randomized capacity-pressure schedules (incl. CoW boundaries), restore
failure fallback, admission under pool exhaustion with the tier on,
two-tier reconciliation, tier-aware E2 costs, and the global
cached-token gauge drift fix."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core import GlobalScheduler, GlobalSchedulerConfig, path_key_of
from repro.core.request import Request, RequestState
from repro.models import zoo
from repro.serving.cluster import ClusterRuntime
from repro.serving.engine import Engine, EngineConfig
from repro.serving.kv_offload import HostKVStore


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(reduced(ARCHS["smollm-360m"]), n_layers=2,
                              dtype="float32")
    api = zoo.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _econf(**kw):
    base = dict(max_context=64, chunk_size=16, max_batch_tokens=64,
                capacity_tokens=160, page_size=8, paged=True,
                host_capacity_tokens=4096)
    base.update(kw)
    return EngineConfig(**base)


def _mk_requests(cfg, n, shared, tail=8, out=3, seed=2):
    rng = np.random.default_rng(seed)
    return [Request(tokens=tuple(shared)
                    + tuple(rng.integers(1, cfg.vocab_size, tail).tolist()),
                    max_new_tokens=out) for _ in range(n)]


def _drain(eng, target, done, now, max_iters=3000):
    for _ in range(max_iters):
        if len(done) >= target:
            return now
        done += eng.step(now)
        now += 0.01
    raise RuntimeError("engine did not converge")


def _pressure_schedule(cfg, eng, shared, seed):
    """Warm the shared prefix, thrash it out of the device pool with
    unique prompts, re-hit it (restore), and repeat — the randomized
    demote/restore/CoW schedule of the acceptance criteria."""
    rng = np.random.default_rng(seed)
    now, done, n_target = 0.0, [], 0
    for wave in range(3):
        hits = _mk_requests(cfg, 2 + wave % 2, shared,
                            tail=int(rng.integers(5, 10)),
                            out=int(rng.integers(2, 4)),
                            seed=seed + 10 * wave)
        for r in hits:
            eng.scheduler.enqueue(r, now)
        n_target += len(hits)
        now = _drain(eng, n_target, done, now)
        for i in range(4):
            plen = int(rng.integers(35, 50))
            r = Request(tokens=tuple(
                np.random.default_rng(1000 * seed + 10 * wave + i)
                .integers(1, cfg.vocab_size, plen).tolist()),
                max_new_tokens=2)
            eng.scheduler.enqueue(r, now)
            n_target += 1
            now = _drain(eng, n_target, done, now)
    return done


# ---------------------------------------------------------------------------
# HostKVStore unit behavior
# ---------------------------------------------------------------------------

def test_host_store_roundtrip():
    st = HostKVStore()
    kv = {"p0": {"g0": {"k": np.arange(12, dtype=np.float32).reshape(3, 2, 2),
                        "v": np.ones((3, 2, 2), np.float32)}}}
    key = path_key_of(tuple(range(19)))
    st.put(key, start=16, kv=kv, length=3, node_id=7)
    assert key in st and st.used_tokens == 3
    e = st.get(key)
    sl = e.slice(17, 19)
    np.testing.assert_array_equal(sl["p0"]["g0"]["k"],
                                  kv["p0"]["g0"]["k"][1:3])
    st.check_invariants()
    assert st.drop(key) == 3
    assert st.used_tokens == 0 and st.get(key) is None
    st.check_invariants()


def test_host_store_split_follows_radix_split():
    """A node split must split the demoted span so each entry again
    covers exactly its node's tokens — numpy slicing, bit-identical.
    Path-keyed: the TAIL keeps the pre-split key (same end boundary),
    the head part lands under the head's new key."""
    from repro.core.radix_tree import RadixTree
    tree = RadixTree()
    st = HostKVStore()
    tree.split_hooks.append(st.on_split)
    node = tree.insert(range(10))[0]
    kv = {"p0": {"g0": {"k": np.arange(10, dtype=np.float32)[:, None, None]}}}
    st.put(node.path_key, start=0, kv=kv, length=10, node_id=node.node_id)
    tree.insert([0, 1, 2, 3, 99])           # splits node at 4
    tail = node.children[4]
    assert tail.path_key == path_key_of(tuple(range(10)))  # key unchanged
    head_e, tail_e = st.get(node.path_key), st.get(tail.path_key)
    assert head_e.length == 4 and head_e.start == 0
    assert tail_e.length == 6 and tail_e.start == 4
    np.testing.assert_array_equal(
        tail_e.kv["p0"]["g0"]["k"][:, 0, 0], np.arange(4, 10))
    assert st.used_tokens == 10
    st.check_invariants()


# ---------------------------------------------------------------------------
# engine demote/restore: token-exactness vs the dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shared_len,seed", [(32, 1), (29, 2), (32, 3)])
def test_offload_matches_dense_oracle(small_model, shared_len, seed):
    """Fused paged plane WITH the host tier vs the dense reference:
    outputs must be token-identical across randomized demote/restore
    schedules, including CoW (unaligned) reuse boundaries."""
    cfg, api, params = small_model
    shared = tuple(np.random.default_rng(seed)
                   .integers(1, cfg.vocab_size, shared_len).tolist())
    outs = {}
    for mode in ("dense", "offload"):
        eng = Engine(cfg, params, _econf(
            paged=(mode == "offload"),
            host_capacity_tokens=(4096 if mode == "offload" else 0)))
        done = _pressure_schedule(cfg, eng, shared, seed)
        outs[mode] = {tuple(r.tokens): list(r.output_tokens) for r in done}
        if mode == "offload":
            assert eng.stats["demoted_tokens"] > 0, "pressure never demoted"
            assert eng.stats["restored_tokens"] > 0, "re-hits never restored"
            eng.pool.check_invariants()
            eng.host_store.check_invariants()
            assert (eng.scheduler.host_used_tokens
                    == eng.host_store.used_tokens)
            assert set(eng.scheduler._host_lru) == set(eng.host_store.entries)
    assert outs["offload"] == outs["dense"], \
        "host-tier restore diverged from the dense oracle"


def test_restore_is_batched_not_per_token(small_model):
    """All restores staged by one step's admissions flush as ONE
    scatter dispatch."""
    cfg, api, params = small_model
    eng = Engine(cfg, params, _econf())
    shared = tuple(np.random.default_rng(5)
                   .integers(1, cfg.vocab_size, 32).tolist())
    _pressure_schedule(cfg, eng, shared, 5)
    assert eng.stats["restored_tokens"] > 0
    assert eng.stats["restore_dispatches"] <= eng.stats["iterations"]
    # a restore moves whole spans per dispatch, not single tokens
    assert (eng.stats["restored_tokens"]
            >= 8 * eng.stats["restore_dispatches"])


def test_restore_failure_falls_back_to_recompute(small_model):
    """Host entries dying mid-flight (demote cascade between restore
    planning and page allocation) must degrade to recompute — same
    tokens out, restore_failures counted, no wedge."""
    cfg, api, params = small_model
    shared = tuple(np.random.default_rng(7)
                   .integers(1, cfg.vocab_size, 32).tolist())
    outs = {}
    for mode in ("dense", "sabotaged"):
        eng = Engine(cfg, params, _econf(
            paged=(mode == "sabotaged"),
            host_capacity_tokens=(4096 if mode == "sabotaged" else 0)))
        if mode == "sabotaged":
            orig = eng._host_restore_chain

            def chain_then_lose(m, boundary, limit, _orig=orig, _eng=eng):
                # model a concurrent demote cascade blowing the host
                # budget at the raciest point: every planned entry dies
                # between restore planning and staging (_ensure_free
                # runs in between and can host-drop in production)
                plan, end = _orig(m, boundary, limit)
                for key, _, _, _ in plan:
                    _eng.scheduler.drop_host(key)
                return plan, end

            eng._host_restore_chain = chain_then_lose
        done = _pressure_schedule(cfg, eng, shared, 7)
        outs[mode] = {tuple(r.tokens): list(r.output_tokens) for r in done}
        if mode == "sabotaged":
            assert eng.stats["restore_failures"] > 0, \
                "sabotage never hit a planned restore"
            assert eng.stats["restored_tokens"] == 0
            eng.pool.check_invariants()
            eng.host_store.check_invariants()
            assert (eng.scheduler.host_used_tokens
                    == eng.host_store.used_tokens)
    assert outs["sabotaged"] == outs["dense"], \
        "restore-failure fallback diverged from the dense oracle"


def test_oversized_abort_and_exhaustion_with_tier(small_model):
    """Admission hardening with the tier ON: an oversized request still
    aborts cleanly, pool exhaustion under unique traffic still serves
    everything, and both tiers reconcile throughout."""
    cfg, api, params = small_model
    eng = Engine(cfg, params, _econf(capacity_tokens=200,
                                     host_capacity_tokens=300))
    big = Request(tokens=tuple(range(1, 70)), max_new_tokens=8)  # 77 > 64
    eng.scheduler.enqueue(big, 0.0)
    rng = np.random.default_rng(3)
    reqs = [Request(tokens=tuple(rng.integers(1, cfg.vocab_size, 40)
                                 .tolist()), max_new_tokens=3)
            for _ in range(6)]
    now, done = 0.0, []
    for r in reqs:
        eng.scheduler.enqueue(r, now)
    for _ in range(800):
        done += eng.step(now)
        eng.pool.check_invariants()
        eng.host_store.check_invariants()
        assert eng.scheduler.host_used_tokens == eng.host_store.used_tokens
        assert (eng.scheduler.host_used_tokens
                <= eng.scheduler.config.host_capacity_tokens)
        now += 0.01
        if len(done) == len(reqs) + 1:
            break
    assert big.state is RequestState.FAILED
    assert eng.stats["aborted"] == 1
    assert len(done) == len(reqs) + 1, "requests starved under eviction"
    assert eng.scheduler.stats["demoted_tokens"] > 0
    # host capacity of 300 cannot hold all ~258 + prior tokens: LRU
    # entries must have been truly dropped at some point or fit exactly
    assert eng.scheduler.host_used_tokens <= 300


def test_cluster_invariants_and_failover_with_tier(small_model):
    """ClusterRuntime with offload engines: E2 placement + pressure +
    instance failure; check_invariants reconciles pool, host store and
    global gauges at every step."""
    cfg, api, params = small_model
    rt = ClusterRuntime(cfg, params, num_instances=2,
                        engine_cfg=_econf(capacity_tokens=220,
                                          host_capacity_tokens=2048))
    rng = np.random.default_rng(11)
    shared = tuple(rng.integers(1, cfg.vocab_size, 24).tolist())
    reqs = []
    for i in range(10):
        if i % 2 == 0:
            toks = shared + tuple(rng.integers(1, cfg.vocab_size, 8).tolist())
        else:
            toks = tuple(rng.integers(1, cfg.vocab_size, 40).tolist())
        reqs.append(Request(tokens=toks, max_new_tokens=2,
                            arrival_time=0.05 * i))
    pending = sorted(reqs, key=lambda r: r.arrival_time)
    now, i = 0.0, 0
    failed_once = False
    for _ in range(1500):
        while i < len(pending) and pending[i].arrival_time <= now:
            rt.submit(pending[i], now)
            i += 1
        rt.step(now)
        rt.check_invariants()
        if not failed_once and len(rt.finished) >= 4:
            rt.fail_instance(0, now)
            failed_once = True
        now += 0.01
        if len(rt.finished) == len(reqs):
            break
    assert len(rt.finished) == len(reqs)
    stats = rt.engine_stats()
    assert any(s["demoted_tokens"] > 0 for s in stats.values())


# ---------------------------------------------------------------------------
# tier-aware E2 + gauge drift fix
# ---------------------------------------------------------------------------

def _gs(n=2, **kw):
    base = dict(th_bal=1e9, capacity_tokens=100_000,
                host_capacity_tokens=1_000_000)
    base.update(kw)
    return GlobalScheduler(num_instances=n,
                           config=GlobalSchedulerConfig(**base))


def test_e2_exploits_demoted_prefix_via_restore():
    """A demoted (host-tier) prefix is still an exploit target: restore
    beats recompute-elsewhere, and the decision survives the device
    eviction notification because the node was demoted, not dropped."""
    gs = _gs()
    prefix = list(range(4000))
    d0 = gs.schedule(Request(tokens=tuple(prefix + [1]),
                             max_new_tokens=4), now=0.0)
    spans = [n.span() for n in gs.tree.nodes_cached_on(d0.instance)]
    gs.on_evictions(d0.instance, spans, now=0.1, demoted=spans)
    inst = gs.instances[d0.instance]
    assert inst.host_cached_tokens > 0
    m = gs.tree.match(tuple(prefix + [2]), now=0.2)
    assert m.per_instance_host_len.get(d0.instance, 0) >= 4000
    d1 = gs.schedule(Request(tokens=tuple(prefix + [2]),
                             max_new_tokens=4), now=0.2)
    assert d1.mode == "exploit"
    assert d1.instance == d0.instance
    # restore is priced: cheaper than a full recompute, dearer than free
    cm = gs.cost_model
    assert 0 < cm.restore_time(4000) < cm.prefill_time(4000)


def test_e2_host_dropped_prefix_is_gone():
    """host_dropped notification truly kills the prefix: next request
    explores instead of exploiting a ghost."""
    gs = _gs()
    prefix = list(range(3000))
    d0 = gs.schedule(Request(tokens=tuple(prefix + [1]),
                             max_new_tokens=4), now=0.0)
    spans = [n.span() for n in gs.tree.nodes_cached_on(d0.instance)]
    gs.on_evictions(d0.instance, spans, now=0.1, demoted=spans)
    gs.on_evictions(d0.instance, [], now=0.2, host_dropped=spans)
    assert gs.instances[d0.instance].host_cached_tokens == 0
    m = gs.tree.match(tuple(prefix + [2]), now=0.3)
    assert m.per_instance_host_len.get(d0.instance, 0) == 0
    assert m.per_instance_len.get(d0.instance, 0) == 0


def test_reserve_rechecks_host_chain_after_eviction_cascade():
    """A reservation whose own eviction demotes enough KV to overflow
    the host budget — dropping the very entries it matched — must not
    book a restore for vanished KV (the simulator would otherwise
    charge restore_time for a full recompute)."""
    from repro.core import (AccountingHostTier, LocalScheduler,
                            LocalSchedulerConfig)
    ls = LocalScheduler(
        LocalSchedulerConfig(instance_id=0, capacity_tokens=1000,
                             chunk_size=4096, max_batch_tokens=8192,
                             host_capacity_tokens=1200),
        host_tier=AccountingHostTier())
    A = tuple(range(10_000, 10_800))
    B = tuple(range(20_000, 20_900))

    def serve(tokens):
        r = Request(tokens=tokens, max_new_tokens=2)
        ls.enqueue(r, 0.0)
        done, now = [], 0.0
        while not done:
            now += 0.01
            done = ls.complete_iteration(ls.form_batch(now), now)
        return r

    serve(A + (1,))
    serve(B + (2,))                      # evicts+demotes A (host: 800)
    assert any(t >= 800 for t in ls._host_lru.values())
    rehit = Request(tokens=A + (3,), max_new_tokens=2)
    ls.enqueue(rehit, 10.0)
    ls.form_batch(10.01)                 # reserve: demotes B -> drops A
    assert ls.host_used_tokens <= 1200
    # A's entry was host-dropped by the cascade: nothing restorable
    a_alive = any(t >= 800 for t in ls._host_lru.values())
    if not a_alive:
        assert rehit.restored_len == 0, \
            "booked a restore for a host entry the cascade dropped"


def test_demote_and_host_drop_same_notification_prunes():
    """A node demoted AND host-dropped in one notification (demote
    cascade overflowing the host budget within one eviction plan) is
    dead in both tiers and must be pruned, not leaked."""
    gs = _gs()
    prefix = list(range(2000))
    d0 = gs.schedule(Request(tokens=tuple(prefix), max_new_tokens=4),
                     now=0.0)
    gs.tree.window = 0.0            # age out window-H hits
    spans = [n.span() for n in gs.tree.nodes_cached_on(d0.instance)]
    gs.on_evictions(d0.instance, spans, now=1e9, demoted=spans,
                    host_dropped=spans)
    assert gs.tree.total_nodes() == 0, "dead dual-tier node leaked"
    assert gs.instances[d0.instance].host_cached_tokens == 0


def test_host_gauge_survives_restore_redemote_cycle():
    """The host gauge mirrors host_instances marking: restore keeps the
    entry resident (no subtract), re-demotion must not double-add, and
    the eventual host drop zeroes it exactly."""
    gs = _gs()
    prefix = list(range(1500))
    d0 = gs.schedule(Request(tokens=tuple(prefix + [1]),
                             max_new_tokens=4), now=0.0)
    inst = gs.instances[d0.instance]
    spans = [n.span() for n in gs.tree.nodes_cached_on(d0.instance)]
    gs.on_evictions(d0.instance, spans, now=0.1, demoted=spans)
    first = inst.host_cached_tokens
    assert first > 0
    # restore (exploit re-hit) — entry stays resident host-side
    gs.schedule(Request(tokens=tuple(prefix + [2]), max_new_tokens=4),
                now=0.2)
    assert inst.host_cached_tokens == first
    # re-demotion of the restored nodes: no double count
    spans2 = [n.span() for n in gs.tree.nodes_cached_on(d0.instance)]
    gs.on_evictions(d0.instance, spans2, now=0.3, demoted=spans2)
    assert inst.host_cached_tokens <= first + 10  # only new split tails
    # final host drop zeroes the gauge without relying on the clamp
    all_host = [n.span() for n in gs.tree.iter_nodes()
                if d0.instance in n.host_instances]
    gs.on_evictions(d0.instance, [], now=0.4, host_dropped=all_host)
    assert inst.host_cached_tokens == 0


def test_global_cached_gauge_accounts_unclamped():
    """Gauge drift fix: additions accrue unclamped so eviction
    subtractions (full node lengths) land on the right base; reads
    clamp at capacity."""
    gs = _gs(n=1, capacity_tokens=1000)
    inst = gs.instances[0]
    gs.schedule(Request(tokens=tuple(range(900)), max_new_tokens=4), 0.0)
    gs.schedule(Request(tokens=tuple(range(5000, 5900)),
                        max_new_tokens=4), 0.1)
    # two 900-token explores: raw gauge 1800 (old code clamped at 1000)
    assert inst.cached_tokens == 1800
    assert inst.device_cached_est() == 1000
    spans = [n.span() for n in gs.tree.nodes_cached_on(0)
             if n.tokens[0] == 0]
    gs.on_evictions(0, spans, now=0.2)
    # subtracting the evicted 900 leaves the OTHER prompt's 900 intact
    # (the old clamped gauge would understate this as 100)
    assert inst.cached_tokens == 900


def test_simulator_surfaces_tier_counters():
    """SimResult reports per-tier counters, and a capacity-pressured
    run with the tier on actually restores."""
    from repro.serving.simulator import simulate

    def mk_reqs():
        rng = np.random.default_rng(0)
        prefixes = [tuple(rng.integers(1, 50000, 3000).tolist())
                    for _ in range(6)]
        reqs, t = [], 0.0
        for _round in range(3):
            for pref in prefixes:
                reqs.append(Request(
                    tokens=pref + tuple(rng.integers(1, 50000, 40).tolist()),
                    max_new_tokens=8, arrival_time=t))
                # spaced so each round is SERVED before the next prefix
                # thrashes it out of the small device pool — the rehit
                # then finds the prefix demoted, not device-resident
                t += 1.0
        return reqs

    res = simulate(mk_reqs(), num_instances=2, capacity_tokens=5000,
                   host_capacity_tokens=40_000)
    s = res.summary()
    for key in ("demoted_tokens", "restored_tokens", "restore_hit_frac",
                "cache_hit_frac"):
        assert key in s
    assert s["demoted_tokens"] > 0
    assert s["restored_tokens"] > 0
    assert s["restore_hit_frac"] > 0
    base = simulate(mk_reqs(), num_instances=2, capacity_tokens=5000,
                    host_capacity_tokens=0).summary()
    assert base["restored_tokens"] == 0
    assert base["restore_hit_frac"] == 0
