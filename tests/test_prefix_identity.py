"""Content-addressed prefix identity + tier-to-tier migration
(DESIGN.md §9).

Covers the acceptance criteria of the path-keyed refactor:

  * path keys are maintained incrementally through inserts/splits and
    name the same content in every tree;
  * the global forest stays consistent with every local scheduler under
    randomized evict/demote/split/host-drop/migrate schedules when the
    local trees allocate node ids INDEPENDENTLY and overlapping (no
    shared counter — ids are deliberately colliding across trees);
  * a crafted digest collision degrades to recompute, never to another
    prefix's KV;
  * a migrated prefix restores on the TARGET instance token-exactly vs
    the dense oracle (real HostKVStore -> HostKVStore bytes);
  * drain migration moves a dying instance's host tier instead of
    recomputing it;
  * the demote DMA double-buffer overlaps compute and reports
    demote_overlap_frac.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core import (AccountingHostTier, GlobalScheduler,
                        GlobalSchedulerConfig, LocalScheduler,
                        LocalSchedulerConfig, PathKey, PrefixSpan,
                        cost_model_for, path_key_of)
from repro.core.radix_tree import _HASH_MOD, RadixTree
from repro.core.request import Request
from repro.models import zoo
from repro.serving.cluster import ClusterRuntime
from repro.serving.engine import Engine, EngineConfig


# ---------------------------------------------------------------------------
# path-key maintenance (unit)
# ---------------------------------------------------------------------------

def test_path_keys_incremental_through_splits():
    t = RadixTree()
    leaf = t.insert(range(10))[0]
    assert leaf.path_key == path_key_of(tuple(range(10)))
    t.insert([0, 1, 2, 3, 99])          # splits at depth 4
    head = t.root.children[0]
    tail = head.children[4]
    # the head gets a fresh key at the new boundary; the TAIL keeps the
    # original key — its end boundary (root->10) is unchanged
    assert head.path_key == path_key_of((0, 1, 2, 3))
    assert tail.path_key == path_key_of(tuple(range(10)))
    assert t.node_by_key(head.path_key) is head
    assert t.node_by_key(tail.path_key) is tail
    assert head.full_tokens() == (0, 1, 2, 3)
    assert tail.full_tokens() == tuple(range(10))


def test_resolve_span_across_differently_split_trees():
    """A span named by one tree's (coarse) node resolves to the chain
    of finer nodes in another tree — the cross-tree protocol core."""
    coarse = RadixTree()
    n = coarse.insert(range(12))[0]
    fine = RadixTree()
    fine.insert(range(12), instance=0)
    fine.insert([0, 1, 2, 7], instance=0)       # boundary at 3
    fine.insert(list(range(8)) + [9], instance=0)  # boundary at 8
    chain = fine.resolve_span(n.span())
    assert sum(len(c.tokens) for c in chain) == 12
    assert [c.path_key.depth for c in chain] == [12, 8, 3]


def test_collision_is_ambiguous_and_verifiable():
    """Two different paths with identical (digest, depth): index marks
    the key ambiguous; only full-path verification resolves it."""
    t = RadixTree()
    a = t.insert([5, 1])[0]
    b = t.insert([5 + _HASH_MOD, 1])[0]
    assert a.path_key == b.path_key
    assert t.key_ambiguous(a.path_key)
    assert t.node_by_key(a.path_key) is None
    assert t.node_by_key(a.path_key, tokens=(5, 1)) is a
    assert t.node_by_key(a.path_key, tokens=(5 + _HASH_MOD, 1)) is b
    assert t.resolve_span(a.span()) == []       # no-tokens resolution: no-op


# ---------------------------------------------------------------------------
# property: global/local consistency with randomized, colliding node ids
# ---------------------------------------------------------------------------

class _Harness:
    """GlobalScheduler + N LocalSchedulers wired over protocol v2, with
    deliberately overlapping per-instance node-id spaces."""

    def __init__(self, n=3, rng=None, host_cap=4000, dev_cap=1200):
        rng = rng or np.random.default_rng(0)
        self.gs = GlobalScheduler(num_instances=n,
                                  config=GlobalSchedulerConfig(
                                      th_bal=1e9, capacity_tokens=dev_cap,
                                      host_capacity_tokens=host_cap))
        self.locals = {}
        for i in range(n):
            ls = LocalScheduler(
                LocalSchedulerConfig(instance_id=i, capacity_tokens=dev_cap,
                                     chunk_size=4096, max_batch_tokens=8192,
                                     host_capacity_tokens=host_cap),
                host_tier=AccountingHostTier(),
                # ids collide across instances AND with the global tree
                node_id_start=int(rng.integers(0, 5)))
            ls.on_evict = self._notify(i)
            self.locals[i] = ls

    def _notify(self, inst):
        def cb(i, spans, demoted=(), host_dropped=()):
            self.gs.on_evictions(inst, spans, demoted=demoted,
                                 host_dropped=host_dropped)
        return cb

    def serve(self, tokens, now, out=2):
        r = Request(tokens=tuple(tokens), max_new_tokens=out)
        d = self.gs.schedule(r, now)
        if d.migration is not None:
            src = self.locals[d.migration.src]
            spans = src.export_host_span(r.tokens, d.migration.lo,
                                         d.migration.hi)
            acc = self.locals[d.instance].ingest_host_span(r.tokens, spans,
                                                           now)
            if acc:
                self.gs.on_migration(d.migration.src, d.instance, r.tokens,
                                     acc, now)
        ls = self.locals[d.instance]
        ls.enqueue(r, now)
        done, t = [], now
        for _ in range(500):
            t += 0.01
            done = ls.complete_iteration(ls.form_batch(t), t)
            if done:
                break
        assert done, "request starved in property harness"
        self.gs.on_request_complete(r, t)
        return r, d

    def migrate_random(self, rng, now):
        srcs = [i for i, ls in self.locals.items() if ls._host_lru]
        if not srcs:
            return
        si = int(rng.choice(srcs))
        src = self.locals[si]
        key = list(src._host_lru)[int(rng.integers(len(src._host_lru)))]
        nid = src._host_nodes.get(key)
        node = src.tree.get_node(nid) if nid is not None else None
        if node is None:
            return
        end = node.depth_tokens()
        start = end - len(node.tokens)
        if src._host_lru[key] < end - start:
            return                       # partial entries don't migrate
        di = int(rng.choice([i for i in self.locals if i != si]))
        tokens = node.full_tokens()
        spans = src.export_host_span(tokens, start, end)
        acc = self.locals[di].ingest_host_span(tokens, spans, now)
        if acc:
            self.gs.on_migration(si, di, tokens, acc, now)

    def drop_random(self, rng):
        cands = [i for i, ls in self.locals.items() if ls._host_lru]
        if not cands:
            return
        i = int(rng.choice(cands))
        ls = self.locals[i]
        key = list(ls._host_lru)[int(rng.integers(len(ls._host_lru)))]
        ls.drop_host(key)

    def check_consistent(self, probes):
        """The core §9 invariant: for every instance, the global forest
        and the instance's own tree agree on the reusable device/host
        coverage of any prompt — without any shared node-id space."""
        for i, ls in self.locals.items():
            for probe in probes:
                _, gd, gh = self.gs.tree.tiered_match(probe, i)
                _, ld, lh = ls.tree.tiered_match(probe, i)
                assert (gd, gh) == (ld, lh), (
                    f"instance {i}: global ({gd},{gh}) != local ({ld},{lh}) "
                    f"for probe head {probe[:3]}")
        for i, inst in self.gs.instances.items():
            assert inst.cached_tokens >= 0
            assert inst.host_cached_tokens >= 0


@pytest.mark.parametrize("seed", [1, 7, 23])
def test_global_forest_consistency_randomized(seed):
    rng = np.random.default_rng(seed)
    h = _Harness(n=3, rng=rng)
    prefixes = [tuple(rng.integers(1, 1 << 20, int(rng.integers(120, 400)))
                      .tolist()) for _ in range(4)]
    now = 0.0
    probes = []
    for step in range(60):
        now += float(rng.uniform(0.01, 0.2))
        op = rng.random()
        if op < 0.55:
            # shared-prefix hit (splits trees at random suffix points)
            pref = prefixes[int(rng.integers(len(prefixes)))]
            cut = int(rng.integers(len(pref) // 2, len(pref)))
            toks = pref[:cut] + tuple(
                rng.integers(1, 1 << 20, int(rng.integers(1, 30))).tolist())
        elif op < 0.85:
            # unique prompt (drives eviction/demotion pressure)
            toks = tuple(rng.integers(1, 1 << 20,
                                      int(rng.integers(200, 700))).tolist())
        elif op < 0.93:
            h.migrate_random(rng, now)
            continue
        else:
            h.drop_random(rng)
            continue
        r, _ = h.serve(toks, now)
        probes.append(r.tokens)
    probe_set = [probes[int(i)] for i in
                 rng.integers(0, len(probes), 12)] + prefixes
    h.check_consistent(probe_set)
    # the schedule must actually have exercised the tier machinery
    total = {k: sum(ls.stats[k] for ls in h.locals.values())
             for k in ("demoted_tokens", "host_dropped_tokens",
                       "evicted_tokens")}
    assert total["evicted_tokens"] > 0 and total["demoted_tokens"] > 0


def test_collision_degrades_to_recompute_not_corruption():
    """Crafted digest collision: colliding spans are never demoted
    under ambiguous keys, notifications no-op, serving completes, and
    the two prefixes never alias each other's accounting."""
    h = _Harness(n=2, dev_cap=900, host_cap=2000)
    A = (5,) + tuple(range(100, 500))
    B = (5 + _HASH_MOD,) + tuple(range(100, 500))   # collides node-by-node
    now = 0.0
    for _ in range(3):
        for toks in (A + (1,), B + (1,), A + (2,), B + (2,)):
            now += 0.05
            h.serve(toks, now)
        # unique pressure forces evict/demote of the colliding paths
        for j in range(3):
            now += 0.05
            h.serve(tuple(np.random.default_rng(int(now * 100) + j)
                          .integers(1, 1 << 20, 600).tolist()), now)
    skipped = sum(ls.stats["demote_skipped_tokens"]
                  for ls in h.locals.values())
    assert skipped > 0, "collision never hit the demote path"
    for ls in h.locals.values():
        # no entry may sit under an ambiguous key it does not own
        for key, nid in ls._host_nodes.items():
            node = ls.tree.get_node(nid)
            assert node is not None and node.path_key == key
        assert ls.host_used_tokens == sum(ls._host_lru.values())
    for inst in h.gs.instances.values():
        assert inst.cached_tokens >= 0 and inst.host_cached_tokens >= 0


def _mini_ls(host_cap=1000, inst=0):
    return LocalScheduler(
        LocalSchedulerConfig(instance_id=inst, capacity_tokens=4000,
                             chunk_size=4096, max_batch_tokens=8192,
                             host_capacity_tokens=host_cap),
        host_tier=AccountingHostTier())


def test_ingest_needs_shallow_first_and_clamps_partial_residency():
    """Migration target side: a child span only lands after its
    ancestor created the start boundary (the drain path ships
    shallow-first), and an already-resident PARTIAL entry must clamp
    the accepted range to what actually exists."""
    src = _mini_ls()
    T = tuple(range(40_000, 40_010))
    # src: nodes [0,5) and [5,10) both host-resident
    parent = src.tree.insert(T[:5])[-1]
    child = src.tree.insert(T)[-1]
    for n, ln in ((parent, 5), (child, 5)):
        src._host_lru[n.path_key] = ln
        src._host_nodes[n.path_key] = n.node_id
        src.host_used_tokens += ln
        n.host_instances.add(0)
    # child-first is structurally rejected on a fresh target...
    dst = _mini_ls(inst=1)
    spans_child = src.export_host_span(T, 5, 10)
    assert dst.ingest_host_span(T, spans_child, 0.0) == []
    # ...shallow-first transfers everything
    dst2 = _mini_ls(inst=1)
    acc1 = dst2.ingest_host_span(T, src.export_host_span(T, 0, 5), 0.0)
    acc2 = dst2.ingest_host_span(T, src.export_host_span(T, 5, 10), 0.0)
    assert acc1 == [(0, 5)] and acc2 == [(5, 10)]
    assert dst2.host_used_tokens == 10
    # partial residency: target holds only 3 of the 5-token node —
    # accepted must stop at token 3, not claim the full node
    dst3 = _mini_ls(inst=1)
    p3 = dst3.tree.insert(T[:5])[-1]
    dst3._host_lru[p3.path_key] = 3
    dst3._host_nodes[p3.path_key] = p3.node_id
    dst3.host_used_tokens = 3
    p3.host_instances.add(1)
    acc = dst3.ingest_host_span(T, [(0, 5, None)], 0.0)
    assert acc == [(0, 3)], acc


def test_split_during_pending_demote_stays_consistent(small_model):
    """A radix split landing while the span's demote DMA is still in
    flight must force the bytes down first — otherwise the store files
    the full span under the tail key after the scheduler's LRU already
    split it, and the tiers diverge permanently."""
    cfg, api, params = small_model
    eng = Engine(cfg, params, _econf(capacity_tokens=640,
                                     max_context=64))
    ls = eng.scheduler
    toks = tuple(np.random.default_rng(3)
                 .integers(1, cfg.vocab_size, 24).tolist())
    r = Request(tokens=toks, max_new_tokens=2)
    _run_requests(lambda q, t: ls.enqueue(q, t), eng.step, [r])
    node = ls.tree.match(toks).path[-1]
    plan = ls.tree.plan_eviction(0, len(toks) + 2)
    assert any(n is node for n in plan)
    ls.apply_eviction(plan, 1.0)          # demote DISPATCHED, not drained
    assert eng.scheduler.host_tier._pending, "demote landed too early"
    # a diverging prompt splits the demoted node mid-span
    ls.tree.insert(toks[:10] + (7,), now=1.1)
    eng._drain_demotes()
    assert set(ls._host_lru) == set(eng.host_store.entries), \
        "host tiers diverged across a split during pending demote"
    eng.host_store.check_invariants()
    assert ls.host_used_tokens == eng.host_store.used_tokens


def test_hot_prefix_outlives_one_shot_under_host_pressure():
    """The hit-rate-weighted admission must see PRE-eviction heat
    (tree.evict drops the instance's hit history): under host-budget
    pressure a re-hit prefix demotes while a one-shot prompt is
    dropped, not the other way around."""
    ls = LocalScheduler(
        LocalSchedulerConfig(instance_id=0, capacity_tokens=700,
                             chunk_size=4096, max_batch_tokens=8192,
                             host_capacity_tokens=350),
        host_tier=AccountingHostTier())

    def serve(tokens, now):
        r = Request(tokens=tuple(tokens), max_new_tokens=2)
        ls.enqueue(r, now)
        done, t = [], now
        while not done:
            t += 0.01
            done = ls.complete_iteration(ls.form_batch(t), t)
        return r

    hot = tuple(range(10_000, 10_300))
    serve(tuple(range(20_000, 20_300)) + (3,), 0.0)   # one-shot (older)
    serve(hot + (1,), 0.1)
    serve(hot + (2,), 0.2)             # 2nd hit: window-H heat > 1
    # force an eviction pass over everything unpinned. The one-shot is
    # LRU-older, so it demotes first and fills the 350-token budget;
    # the hot span then demotes ONLY because its pre-eviction heat
    # overrides the budget-pressure skip, and the weighted overflow
    # must drop the one-shot, not it.
    serve(tuple(range(30_000, 30_600)) + (4,), 0.3)
    resident_heads = {ls.tree.get_node(nid).full_tokens()[:3]
                      for nid in ls._host_nodes.values()
                      if ls.tree.get_node(nid) is not None
                      and len(ls.tree.get_node(nid).full_tokens()) >= 3}
    assert any(d == hot[:3] for d in resident_heads), \
        "re-hit prefix was dropped instead of demoted"
    assert ls.host_used_tokens <= 350


def test_skipped_demotes_release_pool_pages(small_model):
    """Spans the admission policy skips (one-shot under a tiny host
    budget) must still release their pool tables — otherwise the pages
    leak (unaccounted by the scheduler, unreachable by plan_eviction)
    and the pool wedges."""
    cfg, api, params = small_model
    eng = Engine(cfg, params, _econf(host_capacity_tokens=16))
    rng = np.random.default_rng(13)
    reqs = [Request(tokens=tuple(rng.integers(1, cfg.vocab_size, 40)
                                 .tolist()), max_new_tokens=2)
            for _ in range(14)]
    _run_requests(lambda r, t: eng.scheduler.enqueue(r, t), eng.step, reqs)
    assert eng.scheduler.stats["demote_skipped_tokens"] > 0, \
        "tiny host budget never skipped a demote"
    eng.pool.check_invariants()
    # every surviving node table must belong to a node the tree still
    # device-marks — skipped spans may not pin pages from the grave
    marked = {("node", n.path_key)
              for n in eng.scheduler.tree.nodes_cached_on(0)}
    node_tables = {k for k in eng.pool.tables
                  if isinstance(k, tuple) and k[0] == "node"}
    assert node_tables <= marked, (
        f"leaked node tables: {node_tables - marked}")


# ---------------------------------------------------------------------------
# engine-level: migrated prefix is token-exact vs the dense oracle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(reduced(ARCHS["smollm-360m"]), n_layers=2,
                              dtype="float32")
    api = zoo.build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _econf(**kw):
    base = dict(max_context=64, chunk_size=16, max_batch_tokens=64,
                capacity_tokens=160, page_size=8, paged=True,
                host_capacity_tokens=4096)
    base.update(kw)
    return EngineConfig(**base)


def _run_requests(submit, step, reqs, max_iters=4000):
    now, done = 0.0, []
    for r in reqs:
        submit(r, now)
    for _ in range(max_iters):
        done += step(now)
        now += 0.01
        if len(done) >= len(reqs):
            return done
    raise RuntimeError("did not converge")


def _dense_outputs(cfg, params, reqs):
    eng = Engine(cfg, params, _econf(paged=False, host_capacity_tokens=0))
    done = _run_requests(lambda r, t: eng.scheduler.enqueue(r, t),
                         eng.step, reqs)
    return {tuple(r.tokens): list(r.output_tokens) for r in done}


def _clone(reqs):
    return [Request(tokens=r.tokens, max_new_tokens=r.max_new_tokens)
            for r in reqs]


def _mk_cluster(cfg, params):
    """2-instance offload cluster with organic rebalance OFF (tiny toy
    loads trip th_bal instantly and scatter the warm set) and 70B cost
    pricing, so the migrate-vs-recompute arbitration sees per-token
    prefill dominate the per-transfer constants as it does at scale."""
    econf = _econf()
    return ClusterRuntime(
        cfg, params, num_instances=2, engine_cfg=econf,
        scheduler_cfg=GlobalSchedulerConfig(
            th_bal=1e9, capacity_tokens=econf.capacity_tokens,
            host_capacity_tokens=econf.host_capacity_tokens),
        cost_model=cost_model_for("llama3-70b"))


def _mk_workload(cfg, shared, seed):
    """Warm the shared prefix, thrash it to the host tier with uniques,
    then re-hit it — the re-hits are what migration must serve."""
    rng = np.random.default_rng(seed)
    warm = [Request(tokens=shared + tuple(rng.integers(
                1, cfg.vocab_size, 6).tolist()), max_new_tokens=3)
            for _ in range(2)]
    # enough unique volume that EVERY instance's pool thrashes (E2
    # spreads the flood across the cluster)
    thrash = [Request(tokens=tuple(rng.integers(
                  1, cfg.vocab_size, 44).tolist()), max_new_tokens=2)
              for _ in range(10)]
    rehits = [Request(tokens=shared + tuple(rng.integers(
                  1, cfg.vocab_size, 7).tolist()), max_new_tokens=3)
              for _ in range(3)]
    return warm, thrash, rehits


def test_migrated_prefix_token_exact_vs_dense_oracle(small_model):
    """Rebalance-triggered migration on the REAL byte path: the demoted
    span ships HostKVStore -> HostKVStore and restores on the target;
    outputs must match the dense oracle token-for-token."""
    cfg, api, params = small_model
    shared = tuple(np.random.default_rng(31)
                   .integers(1, cfg.vocab_size, 32).tolist())
    warm, thrash, rehits = _mk_workload(cfg, shared, 31)
    oracle = _dense_outputs(cfg, params,
                            _clone(warm) + _clone(thrash) + _clone(rehits))

    rt = _mk_cluster(cfg, params)
    now, done = 0.0, []

    def pump(reqs, target):
        nonlocal now
        for r in reqs:
            rt.submit(r, now)
        for _ in range(4000):
            done.extend(rt.step(now))
            rt.check_invariants()
            now += 0.01
            if len(done) >= target:
                return
        raise RuntimeError("cluster did not converge")

    # 1. warm, THEN thrash: the warm pair exploits onto one instance
    #    and finishes (unpinning its path) before the unique flood
    #    makes the shared prefix the LRU eviction victim -> demoted
    pump(warm, len(warm))
    pump(thrash, len(warm) + len(thrash))
    srcs = [i for i, e in rt.engines.items()
            if any(k.depth == len(shared)
                   for k in e.scheduler._host_lru)]
    assert srcs, "pressure never demoted the shared prefix"
    i0 = srcs[0]
    # 2. flag i0 heavy: exploit traffic redirects (rebalance) and the
    #    redirect target pulls the demoted span via migration
    rt.gs._redirects = {i0: 1 - i0}
    pump(rehits, len(warm) + len(thrash) + len(rehits))
    assert rt.stats["migrated_tokens"] > 0, "rebalance never migrated"
    tgt = rt.engines[1 - i0]
    assert tgt.stats["restored_tokens"] > 0, \
        "migrated span never restored on the target"
    got = {tuple(r.tokens): list(r.output_tokens) for r in done}
    assert got == oracle, "migrated-prefix outputs diverged from dense"


def test_drain_migrates_host_tier(small_model):
    """Graceful drain ships the dying instance's host entries to a
    survivor; re-hits restore there instead of recomputing, and stay
    token-exact."""
    cfg, api, params = small_model
    shared = tuple(np.random.default_rng(41)
                   .integers(1, cfg.vocab_size, 32).tolist())
    warm, thrash, rehits = _mk_workload(cfg, shared, 41)
    oracle = _dense_outputs(cfg, params,
                            _clone(warm) + _clone(thrash) + _clone(rehits))
    rt = _mk_cluster(cfg, params)
    now, done = 0.0, []

    def pump(reqs, target):
        nonlocal now
        for r in reqs:
            rt.submit(r, now)
        for _ in range(4000):
            done.extend(rt.step(now))
            rt.check_invariants()
            now += 0.01
            if len(done) >= target:
                return
        raise RuntimeError("cluster did not converge")

    pump(warm, len(warm))
    pump(thrash, len(warm) + len(thrash))
    srcs = [i for i, e in rt.engines.items()
            if any(k.depth == len(shared)
                   for k in e.scheduler._host_lru)]
    assert srcs, "pressure never demoted the shared prefix"
    i0 = srcs[0]
    moved = rt.drain_instance(i0, now)
    assert moved > 0, "drain shipped nothing"
    survivor = rt.engines[1 - i0]
    assert survivor.scheduler._host_lru, "survivor host tier empty"
    rt.check_invariants()
    pump(rehits, len(warm) + len(thrash) + len(rehits))
    assert survivor.stats["restored_tokens"] > 0, \
        "drained span never restored on the survivor"
    got = {tuple(r.tokens): list(r.output_tokens) for r in done}
    assert got == oracle, "post-drain outputs diverged from dense"


def test_demote_overlap_stat(small_model):
    """The demote DMA double-buffer: gathers issued before the step's
    model dispatch, bytes landed after — demote_overlap_frac reports
    the overlapped fraction and the store stays exact."""
    cfg, api, params = small_model
    eng = Engine(cfg, params, _econf())
    rng = np.random.default_rng(9)
    reqs = [Request(tokens=tuple(rng.integers(1, cfg.vocab_size, 40)
                                 .tolist()), max_new_tokens=2)
            for _ in range(8)]
    _run_requests(lambda r, t: eng.scheduler.enqueue(r, t), eng.step, reqs)
    assert eng.stats["demote_batches"] > 0, "no demote batches ran"
    assert 0.0 <= eng.stats["demote_overlap_frac"] <= 1.0
    assert eng.stats["demote_batches_overlapped"] > 0, \
        "end-of-step drain never overlapped a model dispatch"
    assert eng.scheduler.host_tier._pending == []
    eng.host_store.check_invariants()
    assert eng.scheduler.host_used_tokens == eng.host_store.used_tokens
