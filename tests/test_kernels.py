"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (deliverable c's per-kernel allclose)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention, lse_merge
from repro.kernels.flash_attention import flash_attention
from repro.kernels.prefix_attention import prefix_attention
from repro.kernels import ops

K0 = jax.random.PRNGKey(0)


def rnd(key, *s, dt=jnp.float32):
    return jax.random.normal(key, s, dt)


FLASH_CASES = [
    # B, H, KH, Sq, Skv, D, causal, window
    (2, 4, 2, 128, 128, 64, True, 0),
    (1, 8, 8, 96, 96, 128, True, 0),      # MHA
    (2, 4, 1, 64, 192, 64, False, 0),     # cross-shape, MQA
    (1, 6, 2, 256, 256, 64, True, 64),    # sliding window
    (2, 2, 2, 40, 72, 32, True, 0),       # non-block-multiple
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention(case):
    B, H, KH, Sq, Skv, D, causal, win = case
    k1, k2, k3 = jax.random.split(K0, 3)
    q = rnd(k1, B, H, Sq, D)
    k = rnd(k2, B, KH, Skv, D)
    v = rnd(k3, B, KH, Skv, D)
    out = flash_attention(q, k, v, causal=causal, window=win,
                          block_q=64, block_k=64, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dt):
    k1, k2, k3 = jax.random.split(K0, 3)
    q = rnd(k1, 1, 4, 64, 64).astype(dt)
    k = rnd(k2, 1, 2, 64, 64).astype(dt)
    v = rnd(k3, 1, 2, 64, 64).astype(dt)
    out = flash_attention(q, k, v, interpret=True, block_q=32, block_k=32)
    exp = ref.flash_attention_ref(q, k, v)
    atol = 3e-5 if dt == jnp.float32 else 3e-2
    np.testing.assert_allclose(out.astype(np.float32),
                               exp.astype(np.float32), atol=atol, rtol=0.05)


DEC_CASES = [(4, 8, 2, 256, 64, 4), (2, 4, 4, 100, 128, 3),
             (1, 16, 8, 512, 64, 8), (3, 6, 6, 64, 32, 1)]


@pytest.mark.parametrize("case", DEC_CASES)
def test_decode_attention(case):
    B, H, KH, S, D, ns = case
    k1, k2, k3 = jax.random.split(K0, 3)
    q = rnd(k1, B, H, D)
    k = rnd(k2, B, KH, S, D)
    v = rnd(k3, B, KH, S, D)
    lens = jnp.asarray(np.random.default_rng(0).integers(1, S + 1, B),
                       jnp.int32)
    out = decode_attention(q, k, v, lens, n_splits=ns, interpret=True)
    exp = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=1e-4)


PRE_CASES = [(4, 8, 2, 256, 32, 64), (2, 4, 4, 128, 16, 128),
             (1, 8, 1, 512, 8, 64)]


@pytest.mark.parametrize("case", PRE_CASES)
def test_prefix_attention(case):
    B, H, KH, Sp, Ss, D = case
    ks_ = jax.random.split(K0, 5)
    q = rnd(ks_[0], B, H, D)
    kp, vp = rnd(ks_[1], KH, Sp, D), rnd(ks_[2], KH, Sp, D)
    ks, vs = rnd(ks_[3], B, KH, Ss, D), rnd(ks_[4], B, KH, Ss, D)
    lens = jnp.asarray(np.random.default_rng(1).integers(1, Ss + 1, B),
                       jnp.int32)
    out = prefix_attention(q, kp, vp, ks, vs, lens, interpret=True)
    exp = ref.prefix_attention_ref(q, kp, vp, ks, vs, lens)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=1e-4)


def test_lse_merge_degenerate():
    """Merge with one side fully masked (-inf m) stays finite."""
    acc = jnp.stack([jnp.zeros((1, 1, 2, 4)), jnp.ones((1, 1, 2, 4))], 2)
    m = jnp.stack([jnp.full((1, 1, 2, 1), -jnp.inf),
                   jnp.zeros((1, 1, 2, 1))], 2)
    l = jnp.stack([jnp.zeros((1, 1, 2, 1)), jnp.ones((1, 1, 2, 1))], 2)
    out = lse_merge(acc, m, l)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(out, jnp.ones((1, 1, 2, 4)), atol=1e-6)


def test_ops_layout_wrappers():
    """ops.py adapts model layout [B,S,H,D] <-> kernel layout."""
    k1, k2, k3 = jax.random.split(K0, 3)
    q = rnd(k1, 2, 32, 4, 16)
    k = rnd(k2, 2, 32, 2, 16)
    v = rnd(k3, 2, 32, 2, 16)
    out = ops.flash_attention(q, k, v, block_q=16, block_k=16,
                              interpret=True)
    exp = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=1e-4)


PAGED_CASES = [(3, 8, 2, 16, 4, 32, 64), (2, 4, 4, 8, 6, 24, 32),
               (1, 16, 8, 32, 3, 16, 128)]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_decode_attention(case):
    """Page-table-driven decode attention == dense-gathered oracle."""
    from repro.kernels.paged_attention import paged_decode_attention
    B, H, KH, page, P, n_pages, D = case
    ks = jax.random.split(K0, 3)
    k_pages = rnd(ks[0], n_pages, page, KH, D)
    v_pages = rnd(ks[1], n_pages, page, KH, D)
    q = rnd(ks[2], B, H, D)
    rng = np.random.default_rng(case[0])
    pt = np.stack([rng.choice(n_pages, P, replace=False)
                   for _ in range(B)])
    lens = rng.integers(1, page * P + 1, B)
    out = paged_decode_attention(q, k_pages, v_pages, jnp.asarray(pt),
                                 jnp.asarray(lens), interpret=True)
    dense_k = jnp.stack([k_pages[pt[b]].reshape(page * P, KH, D)
                         for b in range(B)])
    dense_v = jnp.stack([v_pages[pt[b]].reshape(page * P, KH, D)
                         for b in range(B)])
    exp = ref.decode_attention_ref(q, dense_k.transpose(0, 2, 1, 3),
                                   dense_v.transpose(0, 2, 1, 3),
                                   jnp.asarray(lens))
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=1e-4)


# ragged lens: shorter than one page, mid-page (partial last page),
# page-exact boundary, and the full table
PAGED_EDGE_LENS = [[3, 16, 21, 64], [1, 8, 48, 63], [16, 32, 5, 17]]


@pytest.mark.parametrize("lens", PAGED_EDGE_LENS)
def test_paged_decode_attention_ragged_lens(lens):
    """Per-request lengths hitting every page-boundary edge: len <
    page_size, partial last page, exact page multiple, full table."""
    from repro.kernels.paged_attention import paged_decode_attention
    B, H, KH, page, P, n_pages, D = len(lens), 8, 2, 16, 4, 24, 32
    ks = jax.random.split(K0, 3)
    k_pages = rnd(ks[0], n_pages, page, KH, D)
    v_pages = rnd(ks[1], n_pages, page, KH, D)
    q = rnd(ks[2], B, H, D)
    rng = np.random.default_rng(7)
    pt = np.stack([rng.choice(n_pages, P, replace=False) for _ in range(B)])
    out = paged_decode_attention(q, k_pages, v_pages, jnp.asarray(pt),
                                 jnp.asarray(lens), interpret=True)
    dense_k = jnp.stack([k_pages[pt[b]].reshape(page * P, KH, D)
                         for b in range(B)])
    dense_v = jnp.stack([v_pages[pt[b]].reshape(page * P, KH, D)
                         for b in range(B)])
    exp = ref.decode_attention_ref(q, dense_k.transpose(0, 2, 1, 3),
                                   dense_v.transpose(0, 2, 1, 3),
                                   jnp.asarray(lens))
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=1e-4)


def test_paged_gather_reference_matches_kernel():
    """models/attention.paged_attention 'gather' impl (the CPU engine
    path) == the Pallas kernel (interpret) on the same inputs."""
    from repro.kernels.paged_attention import paged_decode_attention
    from repro.models.attention import paged_attention
    B, H, KH, page, P, n_pages, D = 3, 8, 2, 16, 4, 24, 32
    ks = jax.random.split(K0, 3)
    k_pages = rnd(ks[0], n_pages, page, KH, D)
    v_pages = rnd(ks[1], n_pages, page, KH, D)
    q = rnd(ks[2], B, H, D)
    rng = np.random.default_rng(11)
    pt = jnp.asarray(np.stack([rng.choice(n_pages, P, replace=False)
                               for _ in range(B)]))
    lens = jnp.asarray([5, 31, 64])
    got = paged_attention(q, k_pages, v_pages, pt, lens, impl="gather")
    exp = paged_decode_attention(q, k_pages, v_pages, pt, lens,
                                 interpret=True)
    np.testing.assert_allclose(got, exp, atol=3e-5, rtol=1e-4)
