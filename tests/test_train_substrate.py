"""Training substrate: optimizer math, compression, checkpointing."""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, reduced
from repro.models import zoo
from repro.train import (TrainConfig, init_state, make_train_step,
                         restore_checkpoint, save_checkpoint)
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   clip_by_global_norm, ef8_compress,
                                   ef8_init, global_norm, warmup_cosine)
from repro.train.train_loop import TrainState


def _small_api():
    cfg = dataclasses.replace(reduced(ARCHS["smollm-360m"]), n_layers=2)
    return cfg, zoo.build(cfg)


def _fixed_batch(cfg, B=4, S=32):
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def test_overfit_single_batch():
    cfg, api = _small_api()
    tc = TrainConfig(adamw=AdamWConfig(lr=3e-3), total_steps=200,
                     warmup_steps=5)
    state = init_state(api.init(jax.random.PRNGKey(0)), tc)
    step = jax.jit(make_train_step(api, tc))
    batch = _fixed_batch(cfg)
    losses = []
    for _ in range(80):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.5 * losses[0], losses[::10]


@pytest.mark.parametrize("opts", [
    dict(grad_accum=2), dict(compress_grads=True),
    dict(quant_moments=True), dict(grad_accum=2, compress_grads=True,
                                   quant_moments=True)])
def test_variants_still_learn(opts):
    cfg, api = _small_api()
    tc = TrainConfig(adamw=AdamWConfig(lr=3e-3), total_steps=200,
                     warmup_steps=5, **opts)
    state = init_state(api.init(jax.random.PRNGKey(0)), tc)
    step = jax.jit(make_train_step(api, tc))
    batch = _fixed_batch(cfg)
    first = last = None
    for _ in range(60):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < 0.8 * first, (opts, first, last)


def test_checkpoint_restart_bitexact():
    cfg, api = _small_api()
    tc = TrainConfig(total_steps=20, warmup_steps=2, compress_grads=True)
    state = init_state(api.init(jax.random.PRNGKey(0)), tc)
    step = jax.jit(make_train_step(api, tc))
    batch = _fixed_batch(cfg)
    for _ in range(3):
        state, _ = step(state, batch)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, state.as_dict(), int(state.step))
        restored = TrainState.from_dict(restore_checkpoint(d))
        s1, m1 = step(state, batch)
        s2, m2 = step(restored, batch)
        assert float(m1["loss"]) == float(m2["loss"])
        for a, b in zip(jax.tree.leaves(s1.params),
                        jax.tree.leaves(s2.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keeps_latest_k():
    with tempfile.TemporaryDirectory() as d:
        for s in range(6):
            save_checkpoint(d, {"x": jnp.ones(3) * s}, s, keep=3)
        from repro.train.checkpoint import all_steps
        assert all_steps(d) == [3, 4, 5]


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 10.0) < 1e-5
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_ef8_error_feedback_accumulates():
    """Quantization error is carried, so the SUM of compressed grads
    tracks the sum of true grads (unbiased in the long run)."""
    g = {"w": jnp.linspace(-1, 1, 64)}
    err = ef8_init(g)
    total_c = jnp.zeros(64)
    for _ in range(50):
        c, err = ef8_compress(g, err)
        total_c = total_c + c["w"]
    np.testing.assert_allclose(total_c / 50, g["w"], atol=1e-3)


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, warmup=10, total=100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert abs(float(sched(jnp.int32(10))) - 1.0) < 0.11
    assert float(sched(jnp.int32(100))) <= 0.2


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-10, 10), min_size=4, max_size=4))
def test_adamw_quant_close_to_fp32(vals):
    """int8-moment AdamW steps stay close to fp32-moment steps."""
    p = {"w": jnp.asarray(vals, jnp.float32)}
    g = {"w": jnp.asarray(vals[::-1], jnp.float32) * 0.1}
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.0)
    s32 = adamw_init(p, quant_moments=False)
    s8 = adamw_init(p, quant_moments=True)
    p32, s32 = adamw_update(g, s32, p, cfg, jnp.float32(1e-2))
    p8, s8 = adamw_update(g, s8, p, cfg, jnp.float32(1e-2), quant=True)
    np.testing.assert_allclose(np.asarray(p32["w"]), np.asarray(p8["w"]),
                               atol=2e-3)
