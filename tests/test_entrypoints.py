"""The CLI entry points run end-to-end (reduced sizes): launch.train
(with checkpoint/resume), launch.serve, and the dryrun cell lister."""

import os
import subprocess
import sys
import tempfile

import pytest

ENV = {**os.environ, "PYTHONPATH": "src"}
CWD = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=420):
    return subprocess.run([sys.executable, "-m", *args],
                          capture_output=True, text=True, env=ENV,
                          cwd=CWD, timeout=timeout)


@pytest.mark.slow
def test_train_entrypoint_and_resume():
    with tempfile.TemporaryDirectory() as d:
        r = _run(["repro.launch.train", "--arch", "smollm-360m",
                  "--reduced", "--steps", "8", "--batch", "2",
                  "--seq", "32", "--ckpt-dir", d, "--ckpt-every", "4",
                  "--log-every", "4"])
        assert r.returncode == 0, r.stderr[-2000:]
        assert "loss=" in r.stdout
        # resume from checkpoint
        r2 = _run(["repro.launch.train", "--arch", "smollm-360m",
                   "--reduced", "--steps", "12", "--batch", "2",
                   "--seq", "32", "--ckpt-dir", d, "--log-every", "4"])
        assert r2.returncode == 0, r2.stderr[-2000:]
        assert "resumed from step 8" in r2.stdout


@pytest.mark.slow
def test_serve_entrypoint():
    r = _run(["repro.launch.serve", "--arch", "smollm-360m",
              "--instances", "2", "--requests", "8",
              "--max-context", "64"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "finished=8/8" in r.stdout
    assert "prefix reuse" in r.stdout


def test_dryrun_list():
    r = _run(["repro.launch.dryrun", "--list"], timeout=120)
    assert r.returncode == 0, r.stderr[-2000:]
    out = r.stdout
    assert out.count("RUN") == 33
    assert out.count("SKIP") == 7          # long_500k on full-attention
    assert "rwkv6-7b                 long_500k    RUN" in out
