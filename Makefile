PY ?= python

.PHONY: ci test bench-engine install

install:
	$(PY) -m pip install -e .[test]

# tier-1 verify (ROADMAP.md): full suite, fail fast
ci:
	PYTHONPATH=src $(PY) -m pytest -x -q

test:
	PYTHONPATH=src $(PY) -m pytest -q

bench-engine:
	PYTHONPATH=src $(PY) -m benchmarks.bench_engine
