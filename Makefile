PY ?= python

.PHONY: ci ci-fast test bench-engine bench-smoke install

install:
	$(PY) -m pip install -e .[test]

# tier-1 verify (ROADMAP.md): full suite, fail fast
ci:
	PYTHONPATH=src $(PY) -m pytest -x -q

# fast tier-1: the non-slow suite (which includes the mixed-batching
# tests) + the seconds-scale capacity-pressure smoke bench — use for
# inner-loop iteration; `ci` remains the full gate
ci-fast:
	PYTHONPATH=src $(PY) -m pytest -q -m "not slow" tests
	$(MAKE) bench-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -q

bench-engine:
	PYTHONPATH=src $(PY) -m benchmarks.bench_engine

# tiny capacity-pressure + rebalance-under-load + prefetch benches
# (DESIGN.md §8/§9/§10): assert the host tier restores under thrash
# and improves p99, that tier-to-tier migration beats
# drop-and-recompute when Th_bal redirects a hot prefix, and that
# speculative restore overlaps the restore DMA with queue wait
# (fails if prefetch_overlap_frac is 0 with the feature on) — run in
# seconds, results land in results/bench/bench_offload.{csv,json} +
# bench_migration.{csv,json} + bench_prefetch.{csv,json}
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_offload
	PYTHONPATH=src $(PY) -m benchmarks.bench_migration
	PYTHONPATH=src $(PY) -m benchmarks.bench_prefetch
