PY ?= python

.PHONY: ci ci-fast test bench-engine bench-smoke chaos-smoke obs-smoke \
	shard-smoke spec-smoke install

install:
	$(PY) -m pip install -e .[test]

# tier-1 verify (ROADMAP.md): full suite, fail fast
ci:
	PYTHONPATH=src $(PY) -m pytest -x -q

# fast tier-1: the non-slow suite (which includes the mixed-batching
# tests) + the seconds-scale capacity-pressure smoke bench — use for
# inner-loop iteration; `ci` remains the full gate
ci-fast:
	PYTHONPATH=src $(PY) -m pytest -q -m "not slow" tests
	$(MAKE) bench-smoke
	$(MAKE) chaos-smoke
	$(MAKE) obs-smoke
	$(MAKE) shard-smoke
	$(MAKE) spec-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -q

bench-engine:
	PYTHONPATH=src $(PY) -m benchmarks.bench_engine

# tiny capacity-pressure + rebalance-under-load + prefetch benches
# (DESIGN.md §8/§9/§10): assert the host tier restores under thrash
# and improves p99, that tier-to-tier migration beats
# drop-and-recompute when Th_bal redirects a hot prefix, and that
# speculative restore overlaps the restore DMA with queue wait
# (fails if prefetch_overlap_frac is 0 with the feature on) — run in
# seconds, results land in results/bench/bench_offload.{csv,json} +
# bench_migration.{csv,json} + bench_prefetch.{csv,json}
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_offload
	PYTHONPATH=src $(PY) -m benchmarks.bench_migration
	PYTHONPATH=src $(PY) -m benchmarks.bench_prefetch

# fault-injection gate (DESIGN.md §11): the sim-plane chaos harness
# (one crash + 5% DMA loss + 2% notification drop over a seed matrix;
# fails on hung requests, invariant violations, inexact post-anti-
# entropy gauges, or >5x p99 TTFT degradation) plus the real-engine
# crash-mid-wave recovery test on the fused+tiered+prefetch plane
chaos-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_chaos
	PYTHONPATH=src $(PY) -m pytest -q tests/test_faults.py -k "crash_mid_wave"

# observability gate (DESIGN.md §12): same seed three ways (telemetry
# absent / disabled / enabled) — fails if callback gauges drift from
# live scheduler truth or post-anti-entropy residency digests, if any
# request's TTFT/latency breakdown doesn't sum to the measurement
# within 1e-9, if a trace leaks an open span, if enabling telemetry
# perturbs results at all, or if its wall-clock overhead is unbounded
obs-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_obs

# SPMD data-plane gate (DESIGN.md §13): real engine forwards at TP
# 1/2/4 on an emulated CPU mesh, fixed per-chip pool — fails unless
# every run is token-exact vs the single-device dense oracle, the
# fused plane stays at exactly 1.0 model dispatches/iteration, and
# pooled device KV capacity scales linearly with the mesh; emits the
# per-shard DMA/collective/occupancy breakdown to
# results/bench/bench_spmd.{csv,json}
shard-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_spmd

# speculative-decoding gate (DESIGN.md §14): the fused draft-propose +
# target-verify plane on a calibrated 100%-acceptance model pair
# (target = draft + identity tail layers) — fails unless the greedy
# speculative run is token-exact vs the non-speculative fused
# baseline, the engine stays at exactly 1.0 TARGET dispatches per
# iteration (verify lanes ride the one mixed dispatch), realized
# acceptance is ~1.0, and p50 decode throughput improves >= 1.5x;
# emits per-run + breakdown tables to results/bench/bench_spec.*
spec-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_spec
