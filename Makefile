PY ?= python

.PHONY: ci ci-fast test bench-engine bench-smoke install

install:
	$(PY) -m pip install -e .[test]

# tier-1 verify (ROADMAP.md): full suite, fail fast
ci:
	PYTHONPATH=src $(PY) -m pytest -x -q

# fast tier-1: the non-slow suite (which includes the mixed-batching
# tests) + the seconds-scale capacity-pressure smoke bench — use for
# inner-loop iteration; `ci` remains the full gate
ci-fast:
	PYTHONPATH=src $(PY) -m pytest -q -m "not slow" tests
	$(MAKE) bench-smoke

test:
	PYTHONPATH=src $(PY) -m pytest -q

bench-engine:
	PYTHONPATH=src $(PY) -m benchmarks.bench_engine

# tiny capacity-pressure bench (KV offload on vs off, DESIGN.md §8):
# asserts the host tier restores under thrash and improves p99 — runs
# in seconds, results land in results/bench/bench_offload.{csv,json}
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.bench_offload
