PY ?= python

.PHONY: ci ci-fast test bench-engine install

install:
	$(PY) -m pip install -e .[test]

# tier-1 verify (ROADMAP.md): full suite, fail fast
ci:
	PYTHONPATH=src $(PY) -m pytest -x -q

# fast tier-1: the non-slow suite (which includes the mixed-batching
# tests) — use for inner-loop iteration; `ci` remains the full gate
ci-fast:
	PYTHONPATH=src $(PY) -m pytest -q -m "not slow" tests

test:
	PYTHONPATH=src $(PY) -m pytest -q

bench-engine:
	PYTHONPATH=src $(PY) -m benchmarks.bench_engine
